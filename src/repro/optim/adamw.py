"""AdamW + schedules, distribution-friendly.

The optimizer state mirrors the parameter pytree (same logical axes), so
GSPMD shards moments exactly like parameters — with FSDP rules this is
ZeRO-style optimizer-state sharding for free.  Master weights and moments
are fp32; parameters may be bf16 (mixed precision).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params: Pytree) -> Dict[str, Pytree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: AdamWConfig,
    params: Pytree,
    grads: Pytree,
    state: Dict[str, Pytree],
) -> Tuple[Pytree, Dict[str, Pytree], Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = cosine_schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "mu": jax.tree.unflatten(treedef, new_mu),
            "nu": jax.tree.unflatten(treedef, new_nu),
            "count": count,
        },
        metrics,
    )
