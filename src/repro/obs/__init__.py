"""repro.obs — the unified telemetry layer.

One process-wide subsystem, near-zero overhead when disabled, shared by
translate / simulate / search / serve:

* :mod:`repro.obs.telemetry`  hierarchical spans (name, wall time, attrs,
                              parent), pool-worker export/merge;
* :mod:`repro.obs.metrics`    counters / gauges / histograms (p50/p99) in a
                              snapshot-able registry — the payload of the
                              planned translation-daemon metrics endpoint;
* :mod:`repro.obs.stallprof`  per-instruction, per-reason stall attribution
                              from the event-driven simulator (books balance
                              exactly against ``SimResult.issue_stalls``);
* :mod:`repro.obs.export`     JSONL event log + Chrome trace-format
                              (``chrome://tracing`` / Perfetto) exporters.

Typical use::

    from repro import obs

    obs.enable()
    ... run translations / searches ...
    obs.write_trace("trace.json")          # load in Perfetto
    print(obs.metrics().snapshot())

Instrumentation sites call ``obs.span(...)`` unconditionally: with
telemetry disabled that is one attribute check returning a shared no-op,
which is what keeps the disabled-mode tax unmeasurable (see
``BENCH_obs.json``).
"""

from .export import chrome_trace, to_jsonl, write_trace
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, hit_rate
from .stallprof import REASONS, InstrStall, StallProfile, build_profile
from .telemetry import (
    DEFAULT_TELEMETRY,
    NULL_SPAN,
    Span,
    SpanRecord,
    Telemetry,
    disable,
    enable,
    enabled,
    get_telemetry,
    metrics,
    reset,
    span,
)

__all__ = [
    "chrome_trace",
    "to_jsonl",
    "write_trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "hit_rate",
    "REASONS",
    "InstrStall",
    "StallProfile",
    "build_profile",
    "DEFAULT_TELEMETRY",
    "NULL_SPAN",
    "Span",
    "SpanRecord",
    "Telemetry",
    "disable",
    "enable",
    "enabled",
    "get_telemetry",
    "metrics",
    "reset",
    "span",
]
