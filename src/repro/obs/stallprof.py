"""Stall-attribution profiles: where the simulator's stall cycles went.

RegDem's predictor (arXiv 1907.02894 §5) models *aggregate* instruction
stalls; this module keeps the books per **static instruction** and per
**reason**, from the event-driven simulator's own idle accounting:

* ``memory_latency`` — a warp sat on a scoreboard barrier set by a memory
  instruction (the LDG/LDS/LDL whose latency the schedule failed to hide);
* ``barrier_wait``   — same, but the setter was a compute producer
  (FP64/SFU/long-latency ALU);
* ``unit_busy``      — a warp was ready but its functional unit had no
  issue capacity left (the §5.5 ``md`` story: FP64-bound kernels gain
  nothing from occupancy because this bucket dominates);
* ``bank_conflict``  — blocked re-issuing behind an operand-read extended
  by register-bank conflicts;
* ``issue_stall``    — blocked by the instruction's own scheduled stall
  count (fixed-latency dependencies).

The attribution is **exact by construction**: every idle cycle the engine
counts lands in exactly one ``(instruction, reason)`` bucket, so
``profile.total == SimResult.issue_stalls`` always — pinned across all nine
paper benchmarks × every architecture by ``tests/test_stall_profile.py``.

This module is deliberately dependency-free (no ``repro.core`` imports):
the simulator imports it, never the reverse.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

#: Attribution reasons, display order.
REASONS: Tuple[str, ...] = (
    "memory_latency",
    "barrier_wait",
    "unit_busy",
    "bank_conflict",
    "issue_stall",
)

R_MEM, R_BAR, R_UNIT, R_BANK, R_STALL = REASONS


def _short(ins) -> str:
    """One instruction as short display text (control comment stripped)."""
    text = ins.render()
    if text.startswith("/*"):
        end = text.find("*/")
        if end != -1:
            text = text[end + 2 :].lstrip()
    return text


@dataclass
class InstrStall:
    """Stall cycles attributed to one static instruction."""

    #: static instruction index (the annotated-disassembly line order)
    index: int
    op: str
    total: int
    reasons: Dict[str, int] = field(default_factory=dict)

    @property
    def top_reason(self) -> str:
        return max(self.reasons, key=lambda r: (self.reasons[r], r)) if self.reasons else ""

    def to_json(self) -> dict:
        return {
            "index": self.index,
            "op": self.op,
            "total": self.total,
            "reasons": dict(sorted(self.reasons.items())),
        }


@dataclass
class StallProfile:
    """Per-instruction, per-reason attribution of one simulation's stalls."""

    kernel_name: str
    arch: str
    #: total attributed stall cycles — exactly ``SimResult.issue_stalls``
    total: int
    per_reason: Dict[str, int]
    #: nonzero entries only, in static program order
    instructions: List[InstrStall]

    def hot(self, n: int = 5) -> List[InstrStall]:
        """The ``n`` most stall-expensive instructions."""
        return sorted(self.instructions, key=lambda e: (-e.total, e.index))[:n]

    def by_index(self) -> Dict[int, InstrStall]:
        return {e.index: e for e in self.instructions}

    def share(self, entry: InstrStall) -> float:
        return entry.total / self.total if self.total else 0.0

    def to_json(self) -> dict:
        return {
            "kernel": self.kernel_name,
            "arch": self.arch,
            "total": self.total,
            "per_reason": {r: self.per_reason.get(r, 0) for r in REASONS},
            "instructions": [e.to_json() for e in self.instructions],
        }

    def render(self, top: int = 8) -> str:
        """Human-readable summary: reason mix, then the hottest lines."""
        lines = [
            f"stall profile {self.kernel_name} (arch={self.arch}): "
            f"{self.total} stall cycles"
        ]
        for r in REASONS:
            c = self.per_reason.get(r, 0)
            if c:
                lines.append(f"  {r:<14s} {c:>10d}  {c / self.total:6.1%}")
        for e in self.hot(top):
            lines.append(
                f"  /*{e.index:04d}*/ {e.op:<40.40s} {e.total:>10d} "
                f"{self.share(e):6.1%}  {e.top_reason}"
            )
        return "\n".join(lines)


def build_profile(
    kernel, blame: Dict[Tuple[int, str], int], total: int
) -> StallProfile:
    """Resolve an engine blame map ``{(instr_uid, reason): cycles}`` against
    the kernel's static instruction stream.

    ``total`` is the engine's aggregate idle count; a mismatch with the
    blame sum is an attribution bug and raises immediately rather than
    shipping books that don't balance.
    """
    attributed = sum(blame.values())
    if attributed != total:
        raise AssertionError(
            f"{kernel.name}: stall attribution does not balance: "
            f"{attributed} attributed vs {total} counted"
        )
    by_uid: Dict[int, Dict[str, int]] = {}
    for (uid, reason), cycles in blame.items():
        if cycles:
            bucket = by_uid.setdefault(uid, {})
            bucket[reason] = bucket.get(reason, 0) + cycles

    per_reason: Dict[str, int] = {}
    instructions: List[InstrStall] = []
    index = 0
    for it in kernel.items:
        if not hasattr(it, "ctrl"):  # Label
            continue
        reasons = by_uid.pop(it.uid, None)
        if reasons:
            instructions.append(
                InstrStall(
                    index=index,
                    op=_short(it),
                    total=sum(reasons.values()),
                    reasons=dict(sorted(reasons.items())),
                )
            )
            for r, c in reasons.items():
                per_reason[r] = per_reason.get(r, 0) + c
        index += 1
    if by_uid:
        raise AssertionError(
            f"{kernel.name}: blame refers to {len(by_uid)} instruction(s) "
            "not in the kernel's static stream"
        )
    return StallProfile(
        kernel_name=kernel.name,
        arch=getattr(kernel, "arch", "maxwell"),
        total=total,
        per_reason=per_reason,
        instructions=instructions,
    )
