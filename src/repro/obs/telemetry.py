"""Hierarchical spans with near-zero overhead when disabled.

One process-wide :class:`Telemetry` instance records *spans* — named,
attributed, parent-linked wall-time intervals — across every subsystem:
pass pipelines, translations, search stages, simulator runs.  The design
constraints, in order:

1. **Disabled is free.**  ``span()`` with telemetry off performs one
   attribute check and returns a shared no-op singleton: no allocation, no
   clock read, no event.  Hot paths (the simulator issues millions of
   instructions per search) can therefore be instrumented at call
   granularity without a measurable disabled-mode tax (pinned by
   ``BENCH_obs.json`` and the ≤2% pipeline-bench budget).
2. **Exception-safe nesting.**  Spans are context managers; an exception
   closes (and records) every open span on the way out, so a crashed
   pipeline still leaves a coherent timeline.
3. **Pool-mergeable.**  Timestamps come from ``time.perf_counter()``
   (CLOCK_MONOTONIC — one clock machine-wide), and every record carries its
   ``pid``, so spans captured in search-pool workers merge into the parent
   timeline exactly like :meth:`repro.core.simcache.SimCache.export` /
   ``merge`` payloads do.

Exporters live in :mod:`repro.obs.export` (JSONL event log, Chrome
trace-format for ``chrome://tracing`` / Perfetto).
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .metrics import MetricsRegistry


@dataclass
class SpanRecord:
    """One closed span: a named wall-time interval with attributes."""

    name: str
    #: perf_counter seconds at span open (monotonic, comparable across
    #: processes on one machine)
    ts: float
    #: wall-time duration in seconds (>= 0)
    dur: float
    span_id: int
    #: enclosing span's id, or None for a root span
    parent_id: Optional[int]
    pid: int
    tid: int
    attrs: Dict[str, object] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "ts": self.ts,
            "dur": self.dur,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "pid": self.pid,
            "tid": self.tid,
            "attrs": dict(self.attrs),
        }


class _NullSpan:
    """The disabled-mode span: a shared, allocation-free no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One live span; records itself on ``__exit__`` (exceptions included)."""

    __slots__ = ("_tel", "name", "attrs", "_t0", "span_id", "parent_id")

    def __init__(self, tel: "Telemetry", name: str, attrs: Dict[str, object]):
        self._tel = tel
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        """Attach attributes to the live span (e.g. an outcome computed
        mid-flight)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tel = self._tel
        self.span_id = tel._next_id()
        stack = tel._stack()
        self.parent_id = stack[-1] if stack else None
        stack.append(self.span_id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        tel = self._tel
        stack = tel._stack()
        # pop back to this span even if an inner span leaked (belt and
        # braces: context-managed spans cannot leak, but a coherent
        # timeline beats an assertion here)
        while stack and stack[-1] != self.span_id:
            stack.pop()
        if stack:
            stack.pop()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        tel.events.append(
            SpanRecord(
                name=self.name,
                ts=self._t0,
                dur=t1 - self._t0,
                span_id=self.span_id,
                parent_id=self.parent_id,
                pid=os.getpid(),
                tid=threading.get_ident(),
                attrs=self.attrs,
            )
        )
        return False


class Telemetry:
    """The process-wide telemetry state: an on/off switch, the recorded
    span list, and the shared :class:`~repro.obs.metrics.MetricsRegistry`."""

    def __init__(self) -> None:
        self.enabled = False
        self.events: List[SpanRecord] = []
        self.registry = MetricsRegistry()
        self._local = threading.local()
        self._id = 0
        self._id_lock = threading.Lock()

    # -- span machinery --------------------------------------------------------

    def _stack(self) -> List[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_id(self) -> int:
        with self._id_lock:
            self._id += 1
            # pid-prefixed so worker-recorded ids never collide with the
            # parent's after a merge (fork copies the counter)
            return (os.getpid() << 20) | (self._id & 0xFFFFF)

    def span(self, name: str, **attrs) -> object:
        """A context-managed span, or the free no-op when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    # -- switch / lifecycle -----------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop every recorded span and metric (the switch is untouched)."""
        self.events.clear()
        self.registry.clear()
        self._local = threading.local()

    # -- pool-worker exchange (mirrors SimCache.export/merge) -------------------

    def event_count(self) -> int:
        return len(self.events)

    def export_events(self, since: int = 0) -> List[SpanRecord]:
        """Spans recorded at index ``since`` onward, as a picklable list
        (a forked pool worker inherits the parent's prefix — export only
        what the task itself added)."""
        return list(self.events[since:])

    def adopt(self, records: List[SpanRecord]) -> int:
        """Merge worker-exported spans into this timeline; returns the
        number adopted.  Records keep their own pid/ids, so the Chrome
        trace renders each worker as its own process row."""
        self.events.extend(records)
        return len(records)

    def snapshot(self) -> Dict[str, object]:
        """Telemetry self-description plus the full metrics snapshot."""
        return {
            "enabled": self.enabled,
            "spans": len(self.events),
            "metrics": self.registry.snapshot(),
        }


#: The process-wide instance every subsystem instruments against.
DEFAULT_TELEMETRY = Telemetry()


def get_telemetry() -> Telemetry:
    return DEFAULT_TELEMETRY


def span(name: str, **attrs) -> object:
    """Module-level shorthand for ``DEFAULT_TELEMETRY.span``."""
    tel = DEFAULT_TELEMETRY
    if not tel.enabled:
        return NULL_SPAN
    return Span(tel, name, attrs)


def enabled() -> bool:
    return DEFAULT_TELEMETRY.enabled


def enable() -> None:
    DEFAULT_TELEMETRY.enable()


def disable() -> None:
    DEFAULT_TELEMETRY.disable()


def reset() -> None:
    DEFAULT_TELEMETRY.reset()


def metrics() -> MetricsRegistry:
    return DEFAULT_TELEMETRY.registry
