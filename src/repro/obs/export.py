"""Telemetry exporters: JSONL event log and Chrome trace-format.

Two machine-readable renderings of one span timeline:

* :func:`to_jsonl` — one JSON object per line per span (plus one trailing
  ``{"kind": "metrics", ...}`` line with the registry snapshot), the
  greppable/streamable archive format;
* :func:`chrome_trace` — the Chrome trace-event format (``"X"`` complete
  events, microsecond timestamps) that loads directly into
  ``chrome://tracing`` or https://ui.perfetto.dev.  Spans recorded in
  search-pool workers carry their own ``pid`` and render as separate
  process rows under the parent timeline.

:func:`write_trace` dispatches on extension: ``.jsonl`` writes the event
log, anything else writes Chrome trace JSON — the single flag behind
``benchmarks.run --trace`` and ``examples/translate_kernel.py --trace``.
"""

from __future__ import annotations

import json
from typing import List, Optional, Sequence

from .telemetry import DEFAULT_TELEMETRY, SpanRecord, Telemetry


def _sorted_events(events: Sequence[SpanRecord]) -> List[SpanRecord]:
    """Deterministic order: by process, thread, then monotonic open time."""
    return sorted(events, key=lambda e: (e.pid, e.tid, e.ts, e.span_id))


def to_jsonl(telemetry: Optional[Telemetry] = None) -> str:
    """The span timeline (+ metrics snapshot) as JSON-lines text."""
    tel = telemetry if telemetry is not None else DEFAULT_TELEMETRY
    lines = [
        json.dumps({"kind": "span", **e.to_json()}, sort_keys=True)
        for e in _sorted_events(tel.events)
    ]
    lines.append(
        json.dumps(
            {"kind": "metrics", "metrics": tel.registry.snapshot()}, sort_keys=True
        )
    )
    return "\n".join(lines) + "\n"


def chrome_trace(telemetry: Optional[Telemetry] = None) -> dict:
    """The span timeline as a Chrome trace-event object.

    Timestamps are microseconds rebased to the earliest span (Perfetto
    dislikes raw multi-hour perf_counter offsets); events are complete
    (``"ph": "X"``) spans sorted by (pid, tid, ts), so ``ts`` is monotonic
    within every row and ``dur`` is never negative.
    """
    tel = telemetry if telemetry is not None else DEFAULT_TELEMETRY
    events = _sorted_events(tel.events)
    t0 = min((e.ts for e in events), default=0.0)
    trace_events = [
        {
            "name": e.name,
            "ph": "X",
            "ts": round((e.ts - t0) * 1e6, 3),
            "dur": round(max(e.dur, 0.0) * 1e6, 3),
            "pid": e.pid,
            "tid": e.tid,
            "args": {str(k): v for k, v in sorted(e.attrs.items())},
        }
        for e in events
    ]
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": {"spans": len(trace_events), "source": "repro.obs"},
    }


def write_trace(path: str, telemetry: Optional[Telemetry] = None) -> str:
    """Write the timeline to ``path``; format chosen by extension
    (``.jsonl`` -> JSON-lines event log, else Chrome trace JSON).
    Returns the format written (``"jsonl"`` or ``"chrome"``)."""
    if path.endswith(".jsonl"):
        payload = to_jsonl(telemetry)
        fmt = "jsonl"
    else:
        payload = json.dumps(chrome_trace(telemetry), sort_keys=True) + "\n"
        fmt = "chrome"
    with open(path, "w") as fh:
        fh.write(payload)
    return fmt
