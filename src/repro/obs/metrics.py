"""Process-wide metrics registry: counters, gauges, histograms.

The repo's diagnostics were ad-hoc one-offs (``SimCache.stats()``,
``TranslationCache.hit_rate``, ``PassStat`` timing lists, per-bench JSON
blobs) with no shared schema.  This module is the one vocabulary they all
speak now:

* :class:`Counter`    monotonically increasing count (cache hits, passes run);
* :class:`Gauge`      last-written value (entries resident, capacity);
* :class:`Histogram`  bounded-reservoir distribution with p50/p99
                      (translate latency, pass wall time);
* :class:`MetricsRegistry`  named get-or-create store, snapshot-able as one
                      plain dict — the payload the planned translation-daemon
                      metrics endpoint will serve (ROADMAP open item 1).

Everything here is stdlib-only and import-light so the hot core modules
(passes, simulator, translator) can depend on it without cycles.  Updates
are a few dict operations — cheap enough to stay always-on at call
granularity; *per-instruction* telemetry stays behind
:func:`repro.obs.telemetry.enabled`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Union


def hit_rate(
    hits: int, misses: int, default: Optional[float] = None
) -> float:
    """The one shared hits/(hits+misses) implementation.

    ``SimCache.hit_rate``, ``TranslationCache.hit_rate``, and
    ``BatchTranslationReport.hit_rate`` all delegate here so the formula
    can never drift apart.  A zero-access denominator has no meaningful
    rate: that raises an explicit :class:`ValueError` — never a bare
    ``ZeroDivisionError`` from deep inside a report — unless the caller
    opts into a ``default`` (display/stats paths pass ``default=0.0``;
    decision paths should let the error surface).
    """
    total = hits + misses
    if not total:
        if default is None:
            raise ValueError(
                "hit rate undefined: no cache accesses recorded "
                "(pass default= for display paths)"
            )
        return default
    return hits / total


class Counter:
    """Monotonically increasing counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge for levels")
        self.value += n

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-written value (a level, not a rate)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Distribution with exact count/sum/min/max and reservoir percentiles.

    Keeps the most recent ``max_samples`` observations (a ring, so a
    long-running service reports *current* latency, not its lifetime
    average) while ``count``/``total`` stay exact over every observation.
    """

    __slots__ = ("max_samples", "count", "total", "vmin", "vmax", "_ring", "_pos")

    def __init__(self, max_samples: int = 2048) -> None:
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self._ring: List[float] = []
        self._pos = 0

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        if self.vmin is None or value < self.vmin:
            self.vmin = value
        if self.vmax is None or value > self.vmax:
            self.vmax = value
        if len(self._ring) < self.max_samples:
            self._ring.append(value)
        else:
            self._ring[self._pos] = value
            self._pos = (self._pos + 1) % self.max_samples

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the resident reservoir (0 if empty)."""
        if not self._ring:
            return 0.0
        ordered = sorted(self._ring)
        rank = min(len(ordered) - 1, max(0, int(round(p / 100.0 * (len(ordered) - 1)))))
        return ordered[rank]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": round(self.mean, 6),
            "min": self.vmin or 0.0,
            "max": self.vmax or 0.0,
            "p50": round(self.percentile(50), 6),
            "p99": round(self.percentile(99), 6),
        }


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named get-or-create metric store, snapshot-able as one plain dict."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def __len__(self) -> int:
        return len(self._metrics)

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, not a {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def clear(self) -> None:
        self._metrics.clear()

    def snapshot(self) -> Dict[str, object]:
        """Every metric as plain JSON-able values, sorted by name."""
        return {name: self._metrics[name].snapshot() for name in sorted(self._metrics)}

    # -- pool-worker exchange (mirrors SimCache.export/merge) -----------------

    def export(self) -> Dict[str, tuple]:
        """Picklable payload for :meth:`merge` (search-pool workers measure
        into a private registry and ship the deltas back on join)."""
        out: Dict[str, tuple] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                out[name] = ("counter", m.value)
            elif isinstance(m, Gauge):
                out[name] = ("gauge", m.value)
            else:
                out[name] = ("histogram", m.count, m.total, m.vmin, m.vmax, list(m._ring))
        return out

    def merge(self, exported: Dict[str, tuple]) -> None:
        """Adopt an :meth:`export` payload: counters add, gauges last-write,
        histogram observations replay (deterministic given deterministic
        payload order — callers merge in submission order)."""
        for name in sorted(exported):
            payload = exported[name]
            kind = payload[0]
            if kind == "counter":
                self.counter(name).inc(payload[1])
            elif kind == "gauge":
                self.gauge(name).set(payload[1])
            else:
                h = self.histogram(name)
                _, count, total, vmin, vmax, ring = payload
                for v in ring:
                    h.observe(v)
                # replaying the ring undercounts trimmed observations;
                # restore the exact lifetime count/sum/extrema
                h.count += count - len(ring)
                h.total += total - sum(ring)
                if vmin is not None and (h.vmin is None or vmin < h.vmin):
                    h.vmin = vmin
                if vmax is not None and (h.vmax is None or vmax > h.vmax):
                    h.vmax = vmax
