"""Fault-tolerant translation daemon (ROADMAP "translation daemon" item).

:class:`TranslationDaemon` turns the batch
:class:`~repro.core.translator.TranslationService` into a long-running
server with the failure semantics a serving tier needs:

* **async request queue + continuous batching** — ``submit()`` enqueues and
  returns a handle immediately; a bounded worker pool (``max_batch`` slots)
  drains the queue, refilling each slot the moment a request finishes, so
  the daemon never waits for a full batch to form;
* **per-request deadlines** — a watchdog thread scans in-flight requests
  and completes any that blow their deadline *at* the deadline, whether the
  translation is still queued, mid-search, or hung;
* **bounded retry with backoff** — transient failures (an injected fault, a
  quarantine-narrowed search, a crashed worker pool) are retried up to
  ``max_retries`` times with exponential backoff before the daemon gives
  up on the fast path;
* **graceful degradation, never corruption** — when retries are exhausted
  or the deadline fires, the response is the input's **nvcc-baseline
  container bytes** (the do-nothing translation: parse, re-emit, round-trip
  verified) flagged ``degraded``, with the reason attached.  Every response
  is therefore byte-identical to the fault-free translation *or* an
  explicitly-flagged baseline — never silently wrong bytes, never a hang
  past the deadline.  Input that cannot even be parsed
  (:class:`~repro.binary.container.ContainerError`) is a clean ``error``
  response: there is no baseline for garbage.

Completion is **idempotent**: the first completer (worker or watchdog)
wins, a late worker result is counted (``late_results``) and dropped.

Restart durability comes from the layer below: hand the daemon (or its
service) an :class:`~repro.core.artifacts.ArtifactStore` and every tuned
kernel it serves is spilled to disk — a restarted daemon answers repeat
content from the store with zero pipeline passes (``disk_hits`` in
:meth:`metrics_snapshot`).
"""

from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, Optional

from repro import obs
from repro.core.search import SearchConfig
from repro.core.translator import BatchTranslationReport, TranslationService
from repro.obs import Histogram
from repro.testing import faults as _faults

#: response statuses
OK = "ok"
DEGRADED = "degraded"
ERROR = "error"


@dataclass(frozen=True)
class DaemonConfig:
    """Knobs of the serving loop."""

    #: concurrent translation slots (continuous batching width)
    max_batch: int = 4
    #: wall-clock budget per request, submit to response
    deadline_s: float = 30.0
    #: transient-failure retries before degrading (attempts = retries + 1)
    max_retries: int = 2
    #: first retry delay; doubles per retry
    backoff_s: float = 0.05
    #: watchdog scan interval (deadline enforcement granularity)
    watchdog_s: float = 0.005


@dataclass
class DaemonRequest:
    """One unit of work: container bytes plus how to translate them."""

    request_id: int
    data: bytes
    #: "translate" (fixed predictor pipeline) or "tune" (autotuning search)
    mode: str = "translate"
    #: search knobs for ``mode="tune"``
    config: Optional[SearchConfig] = None
    #: per-request deadline override (None = DaemonConfig.deadline_s)
    deadline_s: Optional[float] = None


@dataclass
class DaemonResponse:
    """What a request resolves to — exactly one of three shapes.

    ``status == "ok"``: ``payload`` is the fault-free translation.
    ``status == "degraded"``: ``payload`` is the input's round-trip-verified
    nvcc-baseline bytes and ``reason`` says why the fast path was abandoned.
    ``status == "error"``: ``payload`` is ``None`` (unusable input).
    """

    request_id: int
    status: str
    payload: Optional[bytes] = None
    report: Optional[BatchTranslationReport] = None
    reason: str = ""
    #: translation attempts consumed (0 = never started)
    attempts: int = 0
    latency_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == OK

    @property
    def degraded(self) -> bool:
        return self.status == DEGRADED


class PendingResponse:
    """Caller-side handle: ``result()`` blocks until the daemon responds."""

    def __init__(self, request: DaemonRequest, deadline: float, submitted: float):
        self.request = request
        self.deadline = deadline
        self.submitted = submitted
        self.attempts = 0
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._response: Optional[DaemonResponse] = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def _complete(self, response: DaemonResponse) -> bool:
        """First completer wins; returns whether *this* call won."""
        with self._lock:
            if self._response is not None:
                return False
            self._response = response
        self._event.set()
        return True

    def result(self, timeout: Optional[float] = None) -> DaemonResponse:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id} still pending"
            )
        return self._response


class TranslationDaemon:
    """Supervised serving loop around one :class:`TranslationService`.

    Usable as a context manager; otherwise call :meth:`start` / :meth:`stop`.
    ``service`` defaults to a fresh ``TranslationService(store=store)`` —
    pass ``store`` to make the daemon restart-durable.
    """

    def __init__(
        self,
        service: Optional[TranslationService] = None,
        config: Optional[DaemonConfig] = None,
        store=None,
    ):
        if service is not None and store is not None:
            raise ValueError("pass either a service or a store, not both")
        self.service = service or TranslationService(store=store)
        self.config = config or DaemonConfig()
        self._ids = itertools.count(1)
        self._inflight: Dict[int, PendingResponse] = {}
        self._inflight_lock = threading.Lock()
        self._serve_ms = Histogram()
        self.counters = {
            "requests": 0,
            "ok": 0,
            "degraded": 0,
            "errors": 0,
            "retries": 0,
            "deadline_timeouts": 0,
            "late_results": 0,
        }
        self._counter_lock = threading.Lock()
        self._running = False
        self._pool: Optional[ThreadPoolExecutor] = None
        self._watchdog: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "TranslationDaemon":
        if self._running:
            return self
        self._running = True
        self._pool = ThreadPoolExecutor(
            max_workers=self.config.max_batch,
            thread_name_prefix="regdem-daemon",
        )
        self._watchdog = threading.Thread(
            target=self._watchdog_loop, name="regdem-watchdog", daemon=True
        )
        self._watchdog.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Shut the daemon down.

        ``drain=True`` lets queued/in-flight work finish (the watchdog keeps
        enforcing deadlines throughout, so the wait is bounded by the
        longest outstanding deadline); ``drain=False`` cancels queued work
        and degrades whatever is still pending."""
        if not self._running:
            return
        if self._pool is not None:
            self._pool.shutdown(wait=drain, cancel_futures=not drain)
        self._running = False
        if self._watchdog is not None:
            self._watchdog.join(timeout=2.0)
            self._watchdog = None
        if not drain:
            for pending in self._snapshot_inflight():
                self._finish_degraded(pending, "daemon shutdown")
        self._pool = None

    def __enter__(self) -> "TranslationDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission -----------------------------------------------------------

    def submit(
        self,
        data: bytes,
        mode: str = "translate",
        config: Optional[SearchConfig] = None,
        deadline_s: Optional[float] = None,
    ) -> PendingResponse:
        """Enqueue one request; returns immediately with a handle."""
        if not self._running:
            raise RuntimeError("daemon is not running (use start() or `with`)")
        if mode not in ("translate", "tune"):
            raise ValueError(f"unknown mode {mode!r}")
        req = DaemonRequest(
            request_id=next(self._ids),
            data=data,
            mode=mode,
            config=config,
            deadline_s=deadline_s,
        )
        now = time.monotonic()
        budget = deadline_s if deadline_s is not None else self.config.deadline_s
        pending = PendingResponse(req, deadline=now + budget, submitted=now)
        with self._inflight_lock:
            self._inflight[req.request_id] = pending
        self._count("requests")
        if obs.enabled():
            obs.metrics().counter("daemon.requests").inc()
        self._pool.submit(self._serve, pending)
        return pending

    def request(
        self,
        data: bytes,
        mode: str = "translate",
        config: Optional[SearchConfig] = None,
        deadline_s: Optional[float] = None,
    ) -> DaemonResponse:
        """Blocking convenience wrapper: submit and wait for the response
        (the deadline bounds the wait, so this always returns)."""
        return self.submit(data, mode, config, deadline_s).result()

    # -- the serving path -----------------------------------------------------

    def _serve(self, pending: PendingResponse) -> None:
        from repro.binary.container import ContainerError

        req = pending.request
        backoff = self.config.backoff_s
        last_exc: Optional[BaseException] = None
        for attempt in range(self.config.max_retries + 1):
            if pending.done:  # deadline fired while queued or mid-retry
                return
            pending.attempts = attempt + 1
            try:
                self._inject(req, attempt, pending)
                if pending.done:
                    return
                if req.mode == "tune":
                    payload, report = self.service.tune(req.data, req.config)
                else:
                    payload, report = self.service.translate(req.data)
            except ContainerError as exc:
                # the *input* is unusable: retrying cannot help and there is
                # no baseline to degrade to
                self._finish(
                    pending,
                    DaemonResponse(
                        request_id=req.request_id,
                        status=ERROR,
                        reason=f"invalid input container: {exc}",
                        attempts=pending.attempts,
                    ),
                )
                return
            except Exception as exc:
                last_exc = exc
                self._count("retries")
                if obs.enabled():
                    obs.metrics().counter("daemon.retries").inc()
                if attempt < self.config.max_retries:
                    # waits on the completion event: a deadline completion
                    # aborts the backoff instead of sleeping through it
                    pending._event.wait(backoff)
                    backoff *= 2.0
                continue
            self._finish(
                pending,
                DaemonResponse(
                    request_id=req.request_id,
                    status=OK,
                    payload=payload,
                    report=report,
                    attempts=pending.attempts,
                ),
            )
            return
        self._finish_degraded(
            pending,
            f"translation failed after {pending.attempts} attempt(s): "
            f"{last_exc!r}",
        )

    def _inject(self, req: DaemonRequest, attempt: int, pending: PendingResponse) -> None:
        """Deterministic chaos hooks (no-ops without an installed plan)."""
        inj = _faults.active()
        if inj is None:
            return
        key = str(req.request_id)
        if inj.fire("daemon.latency", key, attempt):
            # a stuck translation: park until the plan's latency elapses or
            # the watchdog completes the request out from under us
            pending._event.wait(inj.plan.latency_s)
        if inj.fire("daemon.error", key, attempt):
            raise _faults.FaultError(
                f"injected daemon.error for request {key} attempt {attempt}"
            )

    def _baseline_bytes(self, data: bytes) -> bytes:
        """The do-nothing translation: parse, re-emit, round-trip verified.

        This is what "degraded" serves — valid container bytes for the
        *input* kernels, zero RegDem passes, never corrupt (the round-trip
        oracle still guards the emission)."""
        from repro.binary import container
        from repro.binary.roundtrip import verified_dumps_many

        return verified_dumps_many(container.loads_many(data))

    def _finish_degraded(self, pending: PendingResponse, reason: str) -> None:
        req = pending.request
        try:
            payload = self._baseline_bytes(req.data)
            status = DEGRADED
        except Exception as exc:  # unusable input: clean error, no bytes
            payload = None
            status = ERROR
            reason = f"{reason}; baseline emission failed: {exc}"
        self._finish(
            pending,
            DaemonResponse(
                request_id=req.request_id,
                status=status,
                payload=payload,
                reason=reason,
                attempts=pending.attempts,
            ),
        )

    def _finish(self, pending: PendingResponse, response: DaemonResponse) -> None:
        response.latency_s = time.monotonic() - pending.submitted
        if not pending._complete(response):
            self._count("late_results")
            if obs.enabled():
                obs.metrics().counter("daemon.late_results").inc()
            return
        with self._inflight_lock:
            self._inflight.pop(pending.request.request_id, None)
        self._serve_ms.observe(response.latency_s * 1e3)
        key = {OK: "ok", DEGRADED: "degraded", ERROR: "errors"}[response.status]
        self._count(key)
        if obs.enabled():
            obs.metrics().counter(f"daemon.{key}").inc()
            obs.metrics().histogram("daemon.serve_ms").observe(
                response.latency_s * 1e3
            )

    # -- deadline watchdog ----------------------------------------------------

    def _snapshot_inflight(self):
        with self._inflight_lock:
            return list(self._inflight.values())

    def _watchdog_loop(self) -> None:
        while self._running:
            now = time.monotonic()
            for pending in self._snapshot_inflight():
                if not pending.done and now >= pending.deadline:
                    self._count("deadline_timeouts")
                    if obs.enabled():
                        obs.metrics().counter("daemon.deadline_timeouts").inc()
                    self._finish_degraded(
                        pending,
                        f"deadline exceeded "
                        f"({now - pending.submitted:.3f}s elapsed)",
                    )
            time.sleep(self.config.watchdog_s)

    # -- introspection --------------------------------------------------------

    def _count(self, key: str) -> None:
        with self._counter_lock:
            self.counters[key] += 1

    def metrics_snapshot(self) -> Dict[str, object]:
        """Daemon health + the wrapped service's snapshot (which carries the
        translation cache's ``disk_hits``/``disk_hit_rate`` and the artifact
        store's stats when a store is attached)."""
        with self._counter_lock:
            counters = dict(self.counters)
        completed = counters["ok"] + counters["degraded"] + counters["errors"]
        snap: Dict[str, object] = {
            "running": self._running,
            "inflight": len(self._inflight),
            "serve_ms": self._serve_ms.snapshot(),
            "completed": completed,
            "degradation_rate": round(
                counters["degraded"] / completed if completed else 0.0, 3
            ),
            "service": self.service.metrics_snapshot(),
        }
        snap.update(counters)
        return snap
