from .trainer import Trainer, TrainConfig
from .serving import Server, ServeConfig

__all__ = ["Trainer", "TrainConfig", "Server", "ServeConfig"]
