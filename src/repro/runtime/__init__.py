from .daemon import DaemonConfig, DaemonRequest, DaemonResponse, TranslationDaemon
from .serving import Server, ServeConfig
from .trainer import TrainConfig, Trainer

__all__ = [
    "Trainer",
    "TrainConfig",
    "Server",
    "ServeConfig",
    "TranslationDaemon",
    "DaemonConfig",
    "DaemonRequest",
    "DaemonResponse",
]
