"""Distributed trainer: step builder + fault tolerance + stragglers.

Production behaviours implemented (and unit-tested):

* **sharded step function** — ``jax.jit`` with explicit in/out shardings
  from the logical-axis rules; optional gradient accumulation via an inner
  ``lax.scan`` over microbatches;
* **checkpoint/restart** — periodic async checkpoints (params + optimizer +
  data cursor); ``run()`` survives injectable step failures by restoring
  the latest checkpoint and replaying the data stream deterministically;
* **straggler mitigation** — per-step wall-time EWMA + z-score detector;
  slow steps raise a counter and a callback (on a real fleet this feeds the
  hot-spare swap; here the hook + detection logic are real and tested);
* **preemption handling** — SIGTERM triggers a final synchronous save;
* **elastic rescale** — ``Trainer.remesh()`` rebuilds the step function on
  a new mesh and reshards state through the checkpoint manager's
  elastic-restore path.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import default_rules, logical_to_sharding

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # gradient accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    fsdp: bool = False
    remat: str = "none"
    attn_impl: str = "chunked"
    straggler_zscore: float = 3.0
    straggler_warmup: int = 8


class StragglerDetector:
    """EWMA + z-score over per-step wall time."""

    def __init__(self, z_threshold: float, warmup: int):
        self.z = z_threshold
        self.warmup = warmup
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            # the first step carries JIT compilation; it would poison the
            # steady-state statistics, so it is never counted
            return False
        if self.n <= self.warmup + 1:
            # prime the statistics
            k = self.n - 1
            self.mean += (dt - self.mean) / k
            self.var += ((dt - self.mean) ** 2 - self.var) / k
            return False
        std = max(self.var**0.5, 1e-9)
        is_straggler = (dt - self.mean) / std > self.z
        alpha = 0.05
        self.mean += alpha * (dt - self.mean)
        self.var += alpha * ((dt - self.mean) ** 2 - self.var)
        if is_straggler:
            self.flagged += 1
        return is_straggler


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        train_cfg: TrainConfig,
        data_cfg: DataConfig,
        mesh: Mesh,
        straggler_callback: Optional[Callable[[int, float], None]] = None,
    ):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.model = Model(model_cfg, attn_impl=train_cfg.attn_impl, remat=train_cfg.remat)
        self.rules = default_rules(
            mesh,
            n_experts=(model_cfg.moe.n_experts if model_cfg.moe else 0),
            fsdp=train_cfg.fsdp,
        )
        self.detector = StragglerDetector(
            train_cfg.straggler_zscore, train_cfg.straggler_warmup
        )
        self.straggler_callback = straggler_callback
        self.ckpt = CheckpointManager(train_cfg.checkpoint_dir, keep=train_cfg.keep_checkpoints)
        self._preempted = False
        self._build()

    # -- construction -----------------------------------------------------------

    def _build(self) -> None:
        model = self.model

        def loss_fn(p, batch):
            return model.train_loss(p, batch)

        def step_fn(params, opt_state, batch):
            if self.cfg.microbatches > 1:
                mb = self.cfg.microbatches

                def micro(carry, mbatch):
                    acc = carry
                    loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                    acc = jax.tree.map(jnp.add, acc, grads)
                    return acc, loss

                split = jax.tree.map(
                    lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
                )
                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                gsum, losses = jax.lax.scan(micro, zero, split)
                grads = jax.tree.map(lambda g: g / mb, gsum)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt, metrics = adamw_update(
                self.opt_cfg, params, grads, opt_state
            )
            metrics["loss"] = loss
            return new_params, new_opt, metrics

        self._step_fn = step_fn

    def init_state(self, rng: Optional[jax.Array] = None) -> Tuple[Pytree, Pytree]:
        rng = rng if rng is not None else jax.random.PRNGKey(self.cfg.seed)
        params, axes = self.model.init(rng)
        self._axes = axes
        shardings = logical_to_sharding(axes, self.mesh, self.rules, like=params)
        params = jax.device_put(params, shardings)
        opt_state = adamw_init(params)
        return params, opt_state

    def param_shardings(self):
        return logical_to_sharding(self._axes, self.mesh, self.rules)

    # -- data ------------------------------------------------------------------

    def _batches(self, start: int) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        pipe = SyntheticLM(self.data_cfg)
        i = start
        while True:
            yield i, pipe.batch(i)
            i += 1

    def _put_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        dp = self.rules.get("batch")
        out = {}
        for k, v in batch.items():
            spec = P(*([dp] + [None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    # -- the run loop (fault-tolerant) -------------------------------------------

    def run(
        self,
        fault_injector: Optional[Callable[[int], None]] = None,
        max_restarts: int = 3,
    ) -> Dict[str, Any]:
        """Train for cfg.steps with checkpoint/restart fault tolerance.

        ``fault_injector(step)`` may raise to simulate a node failure; the
        loop restores from the last checkpoint and continues, replaying the
        deterministic data stream.
        """
        signal_ok = True
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:  # not on main thread (tests)
            signal_ok = False

        restarts = 0
        params, opt_state = self.init_state()
        start_step = 0
        if self.ckpt.latest_step() is not None:
            params, opt_state, start_step = self._restore(params, opt_state)

        losses = []
        step = start_step
        jit_step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        while step < self.cfg.steps:
            try:
                for step, host_batch in self._batches(step):
                    if step >= self.cfg.steps or self._preempted:
                        break
                    t0 = time.perf_counter()
                    if fault_injector is not None:
                        # inside the timed region: injected stalls register
                        # on the straggler detector like real slow nodes
                        fault_injector(step)
                    batch = self._put_batch(host_batch)
                    params, opt_state, metrics = jit_step(params, opt_state, batch)
                    loss = float(metrics["loss"])
                    self._observe_step(step, time.perf_counter() - t0)
                    losses.append(loss)
                    nxt = step + 1
                    if nxt % self.cfg.checkpoint_every == 0 or nxt == self.cfg.steps:
                        self._save(nxt, params, opt_state)
                    step = nxt
                if self._preempted:
                    self._save(step, params, opt_state, async_=False)
                    break
            except Exception:
                restarts += 1
                if restarts > max_restarts:
                    raise
                params, opt_state = self.init_state()
                if self.ckpt.latest_step() is not None:
                    params, opt_state, step = self._restore(params, opt_state)
                else:
                    step = 0
                jit_step = jax.jit(self._step_fn, donate_argnums=(0, 1))
                continue
        self.ckpt.wait()
        return {
            "losses": losses,
            "final_step": step,
            "restarts": restarts,
            "stragglers": self.detector.flagged,
            "params": params,
            "opt_state": opt_state,
        }

    def _observe_step(self, step: int, dt: float) -> None:
        """Straggler pipeline: detector -> mitigation callback (on a real
        fleet the callback triggers the hot-spare swap / slice rebuild)."""
        if self.detector.observe(dt) and self.straggler_callback:
            self.straggler_callback(step, dt)

    # -- checkpoint plumbing -------------------------------------------------------

    def _save(self, step: int, params, opt_state, async_: bool = True) -> None:
        self.ckpt.save(
            step,
            {"params": params, "opt": opt_state},
            extra={"data_index": step},
            async_=async_,
        )

    def _restore(self, params_like, opt_like):
        shardings = {
            "params": self.param_shardings(),
            "opt": {
                "mu": self.param_shardings(),
                "nu": self.param_shardings(),
                "count": NamedSharding(self.mesh, P()),
            },
        }
        state, extra = self.ckpt.restore(
            {"params": params_like, "opt": opt_like}, shardings=shardings
        )
        return state["params"], state["opt"], int(extra["data_index"])

    # -- elastic ---------------------------------------------------------------------

    def remesh(self, new_mesh: Mesh) -> None:
        """Rescale to a different device set: rebuild rules + step function;
        the next restore reshards state onto the new mesh."""
        self.mesh = new_mesh
        self.rules = default_rules(
            new_mesh,
            n_experts=(self.model_cfg.moe.n_experts if self.model_cfg.moe else 0),
            fsdp=self.cfg.fsdp,
        )
        self._build()

    def _on_sigterm(self, signum, frame):  # pragma: no cover - signal path
        self._preempted = True
