"""Batched serving runtime: prefill + decode with continuous batching.

A small but real serving loop:

* fixed-size decode batch with **slot recycling** (continuous batching):
  when a sequence finishes (EOS or max tokens), its slot is refilled from
  the request queue with a fresh prefill — prefill writes into the shared
  KV cache at that slot;
* greedy or temperature sampling;
* the decode step is a single jitted function over the cache pytree — this
  is the ``serve_step`` the decode/long-context dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import Model, ModelConfig
from repro.obs import Histogram

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 512
    max_new_tokens: int = 32
    eos: int = 0
    temperature: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    latency_s: float


class Server:
    """Single-host reference server; the same step functions lower on the
    production mesh (see launch/dryrun.py serve cells)."""

    def __init__(self, model_cfg: ModelConfig, cfg: ServeConfig, params: Pytree):
        self.model = Model(model_cfg, attn_impl="chunked")
        self.cfg = cfg
        self.params = params
        self._decode = jax.jit(self._decode_step)
        self._prefill = jax.jit(self._prefill_fn)
        # serve-level metrics: always on (one histogram append per finished
        # sequence), same shape as TranslationService.metrics_snapshot
        self._latency_ms = Histogram()
        self._tokens_done = 0
        self._busy_seconds = 0.0

    def metrics_snapshot(self) -> dict:
        """Serving health as one plain dict: completion latency distribution
        (p50/p99) and lifetime decode throughput."""
        return {
            "completions": self._latency_ms.count,
            "tokens": self._tokens_done,
            "tokens_per_s": round(
                self._tokens_done / self._busy_seconds, 3
            ) if self._busy_seconds else 0.0,
            "latency_ms": self._latency_ms.snapshot(),
        }

    # -- jitted steps -----------------------------------------------------------

    def _prefill_fn(self, params, tokens):
        h, state = self.model.prefill(params, {"tokens": tokens}, self.cfg.max_len)
        logits = self.model.logits(params, h[:, -1:])
        return logits[:, 0], state

    def _decode_step(self, params, tokens, state):
        h, new_state = self.model.decode_step(params, tokens, state)
        logits = self.model.logits(params, h[:, -1:])
        return logits[:, 0], new_state

    def _sample(self, logits: jax.Array, rng: np.random.Generator) -> np.ndarray:
        if self.cfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        probs = np.asarray(jax.nn.softmax(logits / self.cfg.temperature, axis=-1))
        return np.array(
            [rng.choice(probs.shape[-1], p=probs[i]) for i in range(probs.shape[0])]
        )

    # -- the serving loop ----------------------------------------------------------

    def serve(self, requests: List[Request]) -> List[Completion]:
        t_call = time.perf_counter()
        with obs.span("serve", requests=len(requests)) as sp:
            done = self._serve(requests)
            sp.set(completions=len(done))
        seconds = time.perf_counter() - t_call
        self._busy_seconds += seconds
        for c in done:
            self._latency_ms.observe(c.latency_s * 1e3)
            self._tokens_done += len(c.tokens)
        if obs.enabled():
            reg = obs.metrics()
            reg.counter("serve.completions").inc(len(done))
            reg.histogram("serve.batch_s").observe(seconds)
        return done

    def _serve(self, requests: List[Request]) -> List[Completion]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        pending = queue.SimpleQueue()
        for r in requests:
            pending.put(r)

        # state per slot
        slot_req: List[Optional[Request]] = [None] * cfg.batch_slots
        slot_tokens: List[List[int]] = [[] for _ in range(cfg.batch_slots)]
        slot_start: List[float] = [0.0] * cfg.batch_slots
        done: List[Completion] = []

        state = None
        next_tokens = np.zeros((cfg.batch_slots,), np.int32)

        def fill_slot(slot: int):
            nonlocal state, next_tokens
            if pending.empty():
                slot_req[slot] = None
                return
            req = pending.get()
            slot_req[slot] = req
            slot_tokens[slot] = []
            slot_start[slot] = time.perf_counter()
            prompt = req.prompt[None, :]  # (1, L)
            logits, st = self._prefill(self.params, jnp.asarray(prompt))
            tok = int(self._sample(logits, rng)[0])
            if state is None:
                # first fill: broadcast single-slot state into the batch
                state = self._tree_map_batch(
                    lambda x, ax: jnp.repeat(x, cfg.batch_slots, axis=ax), st
                )
            else:
                state = self._tree_map_batch2(
                    lambda full, one, ax: self._set_slot(full, one, slot, ax), state, st
                )
            slot_tokens[slot].append(tok)
            next_tokens[slot] = tok

        for slot in range(cfg.batch_slots):
            fill_slot(slot)

        while any(r is not None for r in slot_req):
            logits, state = self._decode(
                self.params, jnp.asarray(next_tokens)[:, None], state
            )
            sampled = self._sample(logits, rng)
            for slot, req in enumerate(slot_req):
                if req is None:
                    continue
                tok = int(sampled[slot])
                slot_tokens[slot].append(tok)
                next_tokens[slot] = tok
                if tok == cfg.eos or len(slot_tokens[slot]) >= cfg.max_new_tokens:
                    done.append(
                        Completion(
                            uid=req.uid,
                            tokens=list(slot_tokens[slot]),
                            latency_s=time.perf_counter() - slot_start[slot],
                        )
                    )
                    fill_slot(slot)
        return sorted(done, key=lambda c: c.uid)

    # -- slot surgery -------------------------------------------------------------
    # State leaves keyed by their top-level name:
    #   kv:   (L|apps, B, S, H, Dh) -> batch axis 1
    #   ssm:  (L, B, H, P, N)       -> batch axis 1
    #   conv: (L, B, K, C)          -> batch axis 1
    #   pos:  (B,)                  -> batch axis 0
    #   enc:  (B, T, D)             -> batch axis 0
    _BATCH_AXIS = {"kv": 1, "ssm": 1, "conv": 1, "pos": 0, "enc": 0}

    @classmethod
    def _leaf_axis(cls, path) -> int:
        key = None
        for p in path:
            if hasattr(p, "key"):
                key = str(p.key)
                break
        return cls._BATCH_AXIS.get(key, 0)

    @classmethod
    def _tree_map_batch(cls, fn, tree):
        return jax.tree_util.tree_map_with_path(
            lambda path, x: fn(x, cls._leaf_axis(path)), tree
        )

    @classmethod
    def _tree_map_batch2(cls, fn, tree_a, tree_b):
        return jax.tree_util.tree_map_with_path(
            lambda path, a, b: fn(a, b, cls._leaf_axis(path)), tree_a, tree_b
        )

    @staticmethod
    def _set_slot(full: jax.Array, one: jax.Array, slot: int, ax: int) -> jax.Array:
        idx = [slice(None)] * full.ndim
        idx[ax] = slice(slot, slot + 1)
        return full.at[tuple(idx)].set(one)
