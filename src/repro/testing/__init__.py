"""repro.testing — test-support machinery that ships with the library.

:mod:`repro.testing.faults` is the deterministic fault-injection layer the
chaos suite drives: torn artifact writes, bit-flipped payloads, pool-worker
crashes, injected latency and transient errors.  Production code consults
it through :func:`repro.testing.faults.active`, which is ``None`` unless a
test (or the serve benchmark's fault phase) installed a plan — the
zero-plan fast path is a single global read.
"""

from .faults import FaultError, FaultInjector, FaultPlan, active, install, injected

__all__ = [
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "active",
    "install",
    "injected",
]
