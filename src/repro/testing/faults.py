"""Deterministic, seed-driven fault injection.

The chaos suite's one source of misfortune.  A :class:`FaultPlan` describes
*which* faults to inject — torn artifact writes, bit-flipped payloads,
pool-worker crashes, injected latency, transient translate errors — and a
:class:`FaultInjector` wraps a plan with counters.  Decisions are **pure
functions of (seed, site, key, attempt)**: no hidden RNG state, so the same
plan injects the same faults on every run, in every process (pool workers
included), and a retried attempt rolls a *different* die than the attempt
it is retrying — which is what lets a test script "fail twice, then
succeed".

Sites (the strings production code passes to :meth:`FaultInjector.fire`):

==================  ========================================================
``store.torn``      artifact-store write is torn: the entry file is left
                    truncated on disk, as if the process died mid-write
``store.tmp``       artifact-store write dies *before* the atomic rename:
                    a stale ``*.tmp`` is left behind, the entry never lands
``store.flip``      one bit of a stored payload is flipped on read (media
                    corruption; the store's CRC must catch it)
``worker.crash``    a pool worker hard-exits (``os._exit``) while running
                    the task — only ever consulted inside worker processes
``daemon.error``    a transient translation failure (raises FaultError)
``daemon.latency``  extra seconds of latency injected before translating
==================  ========================================================

Production modules consult the **process-global** injector via
:func:`active` (``None`` when no plan is installed — the only cost in
production is one module-attribute read).  Tests install one with
:func:`install` or the :func:`injected` context manager; the supervised
worker pool forwards the parent's plan to its children so crash schedules
hold across process boundaries.
"""

from __future__ import annotations

import contextlib
import zlib
from dataclasses import dataclass, field, replace
from typing import Dict, Iterator, Optional


class FaultError(RuntimeError):
    """An injected (transient) failure — never raised by real code paths."""


def _roll(seed: int, site: str, key: str, attempt: int) -> float:
    """Deterministic uniform draw in [0, 1) for one (site, key, attempt).

    CRC32 of the identifying string: stable across processes, platforms and
    Python versions (unlike ``hash``), cheap, and good enough to spread
    probabilities — this is a test harness, not a cryptographic sampler.
    """
    h = zlib.crc32(f"{seed}|{site}|{key}|{attempt}".encode("utf-8"))
    return (h & 0xFFFFFFFF) / 4294967296.0


@dataclass(frozen=True)
class FaultPlan:
    """What to inject.  Probabilities are per-(site, key, attempt) and
    decided deterministically; ``schedule`` overrides them with explicit
    "inject the first N attempts of this (site, key)" entries — the tool
    for scripting "this task kills its worker exactly twice"."""

    seed: int = 0
    #: probability a store write is torn (truncated final file)
    torn_write_p: float = 0.0
    #: probability a store write dies before its rename (stale tmp file)
    tmp_write_p: float = 0.0
    #: probability a stored payload suffers a bit flip on read
    bit_flip_p: float = 0.0
    #: probability a pool worker crashes while running a task
    worker_crash_p: float = 0.0
    #: probability one translate attempt raises a transient FaultError
    error_p: float = 0.0
    #: probability of injecting ``latency_s`` before a translate attempt
    latency_p: float = 0.0
    #: seconds of latency injected when the latency die fires
    latency_s: float = 0.0
    #: explicit schedules: ``{(site, key): n}`` injects the fault for
    #: attempts 0..n-1 of that (site, key), regardless of probabilities
    schedule: Dict[tuple, int] = field(default_factory=dict)

    _SITE_P = {
        "store.torn": "torn_write_p",
        "store.tmp": "tmp_write_p",
        "store.flip": "bit_flip_p",
        "worker.crash": "worker_crash_p",
        "daemon.error": "error_p",
        "daemon.latency": "latency_p",
    }

    def decide(self, site: str, key: str = "", attempt: int = 0) -> bool:
        """Should this fault fire?  Pure — same answer every time."""
        n = self.schedule.get((site, key))
        if n is not None:
            return attempt < n
        p = getattr(self, self._SITE_P[site], 0.0)
        if p <= 0.0:
            return False
        if p >= 1.0:
            return True
        return _roll(self.seed, site, key, attempt) < p


class FaultInjector:
    """A :class:`FaultPlan` plus injection counters (what actually fired)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.injected: Dict[str, int] = {}

    def fire(self, site: str, key: str = "", attempt: int = 0) -> bool:
        """Decide and, when firing, count.  Decision is deterministic; the
        counters are this process's observation of it."""
        if self.plan.decide(site, key, attempt):
            self.injected[site] = self.injected.get(site, 0) + 1
            return True
        return False

    def flip_bit(self, data: bytes, site: str = "store.flip", key: str = "") -> bytes:
        """Return ``data`` with one deterministically chosen bit flipped."""
        self.injected[site] = self.injected.get(site, 0) + 1
        if not data:
            return data
        pos = zlib.crc32(f"{self.plan.seed}|pos|{key}".encode()) % len(data)
        bit = zlib.crc32(f"{self.plan.seed}|bit|{key}".encode()) % 8
        out = bytearray(data)
        out[pos] ^= 1 << bit
        return bytes(out)

    def torn_length(self, n: int, key: str = "") -> int:
        """Deterministic truncation point for a torn write of ``n`` bytes:
        strictly less than ``n`` (something was lost) and at least 1 when
        possible (a zero-byte file is the trivially detected case)."""
        if n <= 1:
            return 0
        return 1 + zlib.crc32(f"{self.plan.seed}|torn|{key}".encode()) % (n - 1)

    def counts(self) -> Dict[str, int]:
        return dict(self.injected)


#: process-global injector; ``None`` = no faults (production)
_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    """The installed injector, or ``None`` — the production fast path."""
    return _ACTIVE


def install(plan: Optional[FaultPlan]) -> Optional[FaultInjector]:
    """Install ``plan`` process-wide (``None`` uninstalls).  Returns the
    injector so the caller can read its counters afterwards."""
    global _ACTIVE
    _ACTIVE = FaultInjector(plan) if plan is not None else None
    return _ACTIVE


@contextlib.contextmanager
def injected(plan: FaultPlan) -> Iterator[FaultInjector]:
    """Context manager: install ``plan``, yield the injector, restore the
    previous injector (usually ``None``) on exit."""
    global _ACTIVE
    prev = _ACTIVE
    inj = FaultInjector(plan)
    _ACTIVE = inj
    try:
        yield inj
    finally:
        _ACTIVE = prev


def without_site(plan: FaultPlan, site: str) -> FaultPlan:
    """A copy of ``plan`` with one site's probability zeroed (scheduled
    entries for the site are kept — they are explicit)."""
    attr = FaultPlan._SITE_P[site]
    return replace(plan, **{attr: 0.0})
