"""Jitted public wrappers for the Pallas kernels.

``flash_attention`` adapts (B, S, H, Dh) model-layout operands (GQA grouping
included) onto the (batch*heads)-flattened kernel; ``mamba2_ssd`` wraps the
chunked SSD kernel.  On CPU hosts the wrappers run the kernels in interpret
mode (the TPU target uses the compiled BlockSpec path); both modes share the
same kernel body, which is what the shape/dtype sweep tests validate against
:mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as fa
from . import mamba2_ssd as ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("window", "chunk_attn", "block_q", "block_kv", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    q_positions: jax.Array,   # (B, Sq)
    kv_positions: jax.Array,  # (B, Skv)
    window: Optional[int] = None,
    chunk_attn: Optional[int] = None,
    block_q: Optional[int] = None,
    block_kv: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Model-layout flash attention with VMEM-demoted accumulators."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    interp = (not _on_tpu()) if interpret is None else interpret

    # flatten (B, H) and broadcast GQA groups
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), groups, axis=1).reshape(b * hq, -1, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), groups, axis=1).reshape(b * hq, -1, dh)
    qp = jnp.repeat(q_positions[:, None, :], hq, axis=1).reshape(b * hq, sq)
    kp = jnp.repeat(kv_positions[:, None, :], hq, axis=1).reshape(b * hq, -1)

    out = fa.flash_attention_bh(
        qf, kf, vf, qp, kp,
        window=window, chunk=chunk_attn,
        block_q=block_q, block_kv=block_kv, interpret=interp,
    )
    return out.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "head_block", "interpret"))
def mamba2_ssd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    a: jax.Array,    # (H,)
    bm: jax.Array,   # (B, S, N)
    cm: jax.Array,   # (B, S, N)
    chunk: int = 256,
    head_block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    interp = (not _on_tpu()) if interpret is None else interpret
    return ssd.ssd_pallas(
        x, dt, a, bm, cm, chunk=chunk, head_block=head_block, interpret=interp
    )
