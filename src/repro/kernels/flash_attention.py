"""Flash attention with VMEM-demoted accumulators (the RegDem TPU kernel).

Hardware adaptation of the paper's register demotion (DESIGN.md §2):

* GPU RegDem keeps spilled registers in *shared memory* so occupancy stays
  high.  On TPU the scarce fast tier is VREGs + the per-block working set;
  the software-managed on-chip tier is **VMEM**.  This kernel keeps the
  online-softmax running state — the (bq,) running max ``m``, the (bq,)
  normalizer ``l`` and the (bq, dh) output accumulator — in explicit **VMEM
  scratch** across the KV-block grid dimension, instead of writing per-block
  partial products to HBM and re-normalizing in a second pass (the
  "local-memory spill" analogue a naive lowering produces).
* Block shapes are the register-count analogue: larger (bq, bkv) blocks =
  fewer grid steps (better "single-thread" efficiency) but a larger VMEM
  footprint (lower "occupancy").  :func:`choose_block_sizes` plays the role
  of the paper's occupancy-cliff target chooser: it picks the largest
  MXU-aligned blocks whose working set fits the VMEM budget.

Grid: (batch x heads, q_blocks, kv_blocks) with kv innermost so the scratch
accumulators carry across kv steps; masking supports causal, sliding-window
(gemma3) and chunked (llama4) patterns via position arrays.

Validated against :mod:`repro.kernels.ref` in interpret mode (CPU) across
shape/dtype sweeps; compiled with real BlockSpecs on TPU.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

#: conservative per-core VMEM budget (bytes) for block-size selection
VMEM_BUDGET = 64 * 1024 * 1024
#: MXU tile alignment
LANE = 128
SUBLANE = 8


def _align_up(x: int, unit: int) -> int:
    return -(-x // unit) * unit


def choose_block_sizes(
    seq_q: int, seq_kv: int, head_dim: int, dtype_bytes: int = 2,
    vmem_budget: int = VMEM_BUDGET,
) -> Tuple[int, int]:
    """Pick (bq, bkv): largest MXU-aligned blocks fitting the VMEM budget.

    Working set per grid step (all f32 scratch + operand blocks):
      q (bq, dh) + k (bkv, dh) + v (bkv, dh) + scores (bq, bkv)
      + acc (bq, dh) + m/l (bq) + out (bq, dh)
    Doubled for pipelining (double-buffered HBM->VMEM copies).

    Both returned block sizes are always SUBLANE-aligned and never exceed
    the SUBLANE-rounded sequence length; sequences that are not a multiple
    of the chosen block are padded by :func:`flash_attention_bh` (masked
    via the position arrays), so any (bq, bkv) this returns is launchable.
    """
    def fits(bq: int, bkv: int) -> bool:
        operand = (bq * head_dim + 2 * bkv * head_dim) * dtype_bytes
        scratch = (bq * bkv + 2 * bq * head_dim + 2 * bq) * 4
        return 2 * operand + scratch <= vmem_budget

    # a short sequence gets one SUBLANE-aligned block covering it entirely;
    # longer ones pick from the MXU-friendly ladder (padding covers the
    # partial final block)
    sq = _align_up(max(seq_q, 1), SUBLANE)
    skv = _align_up(max(seq_kv, 1), SUBLANE)
    ladder = [2048, 1024, 512, 256, 128]
    cand_q = [c for c in ladder if c <= sq] or [sq]
    cand_kv = [c for c in ladder if c <= skv] or [skv]
    for bq in cand_q:
        for bkv in cand_kv:
            if fits(bq, bkv):
                return bq, bkv
    return cand_q[-1], cand_kv[-1]


def _attention_kernel(
    # refs (blocked by BlockSpec)
    q_ref,      # (1, bq, dh)
    k_ref,      # (1, bkv, dh)
    v_ref,      # (1, bkv, dh)
    qpos_ref,   # (1, bq)
    kpos_ref,   # (1, bkv)
    o_ref,      # (1, bq, dh)
    # VMEM scratch: the demoted accumulators
    m_scr,      # (bq,)
    l_scr,      # (bq,)
    acc_scr,    # (bq, dh)
    *,
    kv_blocks: int,
    scale: float,
    window: Optional[int],
    chunk: Optional[int],
):
    kv_idx = pl.program_id(2)

    # ---- init demoted accumulators at the first kv block -------------------
    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bkv, dh)
    v = v_ref[0].astype(jnp.float32)
    qp = qpos_ref[0]                            # (bq,)
    kp = kpos_ref[0]                            # (bkv,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bkv)

    ok = jnp.logical_and(kp[None, :] >= 0, kp[None, :] <= qp[:, None])
    if window is not None:
        ok = jnp.logical_and(ok, kp[None, :] > qp[:, None] - window)
    if chunk is not None:
        ok = jnp.logical_and(ok, (kp[None, :] // chunk) == (qp[:, None] // chunk))
    s = jnp.where(ok, s, NEG_INF)

    # ---- online softmax over the demoted state ------------------------------
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_new = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    # ---- final normalization at the last kv block ---------------------------
    @pl.when(kv_idx == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(
    q: jax.Array,        # (BH, Sq, Dh) — batch*heads flattened
    k: jax.Array,        # (BH, Skv, Dh)
    v: jax.Array,        # (BH, Skv, Dh)
    q_positions: jax.Array,   # (BH, Sq) int32
    kv_positions: jax.Array,  # (BH, Skv) int32
    *,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    block_q: Optional[int] = None,
    block_kv: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Core pallas_call on (batch*heads)-flattened operands.

    Sequence lengths need not be multiples of the block sizes (nor of
    SUBLANE): operands are zero-padded up to the next block boundary and
    the padded positions are masked out through the position arrays —
    padded kv rows get position -1 (always masked: ``kp >= 0`` fails) and
    padded q rows produce finite garbage that is sliced off before return.
    """
    bh, sq, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    auto = choose_block_sizes(sq, skv, dh)
    bq = _align_up(min(block_q or auto[0], _align_up(sq, SUBLANE)), SUBLANE)
    bkv = _align_up(min(block_kv or auto[1], _align_up(skv, SUBLANE)), SUBLANE)
    pad_q = _align_up(sq, bq) - sq
    pad_kv = _align_up(skv, bkv) - skv
    if pad_q or pad_kv:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
        # padded kv columns carry position -1: masked out everywhere.
        # padded q rows also carry -1 — their outputs are dropped below.
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad_q)), constant_values=-1)
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_kv)), constant_values=-1)
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    q_blocks, kv_blocks = sq_p // bq, skv_p // bkv

    kernel = functools.partial(
        _attention_kernel,
        kv_blocks=kv_blocks,
        scale=scale,
        window=window,
        chunk=chunk,
    )
    grid = (bh, q_blocks, kv_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bkv), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, dh), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_positions, kv_positions)
    return out[:, :sq] if pad_q else out


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU); plain scratch under interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except (ImportError, AttributeError):  # pragma: no cover
        return pl.MemorySpace.ANY(shape, dtype)  # type: ignore[attr-defined]
