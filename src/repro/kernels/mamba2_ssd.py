"""Mamba2 SSD chunk-scan Pallas kernel with VMEM-resident recurrent state.

The RegDem adaptation for the SSM family (DESIGN.md §2): the inter-chunk
recurrent state ``h (heads_blk, P, N)`` is the demoted register — it lives
in **VMEM scratch** across the chunk-grid dimension instead of being written
back to HBM between chunks (which is what the pure-JAX ``lax.scan``
formulation materializes as carry traffic).

Grid: (batch, head_blocks, chunks) with chunks innermost.  Per step the
kernel computes the intra-chunk quadratic dual form and folds the carried
state, all in fp32 VMEM:

    L        = exp(segsum(dt*a))          (Q, Q) lower-triangular decay
    y_intra  = (C B^T . L . dt) x
    y_inter  = C h_prev . decay_from_start
    h       <- h * exp(sum dt*a) + B^T (dt * decay_to_end * x)

Block shapes: Q (chunk length) x P (head dim) x N (state) are already
MXU-friendly for the assigned configs (Q=256, P=64, N=64/128); the head
dimension is blocked to keep the working set within the VMEM budget.

Validated against :func:`repro.kernels.ref.ssd_reference` in interpret mode.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .flash_attention import _vmem


def _ssd_kernel(
    x_ref,    # (1, 1, Q, hb, P)
    dt_ref,   # (1, 1, Q, hb)
    a_ref,    # (1, hb)
    b_ref,    # (1, 1, Q, N)
    c_ref,    # (1, 1, Q, N)
    y_ref,    # (1, 1, Q, hb, P)
    hlast_ref,  # (1, hb, P, N)
    h_scr,    # VMEM (hb, P, N) — the demoted recurrent state
    *,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)    # (Q, hb, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q, hb)
    a = a_ref[0].astype(jnp.float32)       # (hb,)
    b = b_ref[0, 0].astype(jnp.float32)    # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)    # (Q, N)

    da = dt * a[None, :]                   # (Q, hb)
    da_cum = jnp.cumsum(da, axis=0)        # (Q, hb)
    da_total = da_cum[-1]                  # (hb,)

    # ---- intra-chunk quadratic dual form ------------------------------------
    # L[h, i, j] = exp(da_cum[i,h] - da_cum[j,h]) for i >= j
    diff = da_cum[:, None, :] - da_cum[None, :, :]       # (Q, Q, hb)
    q_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape[:2], 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape[:2], 1)
    tri = (q_idx >= k_idx)[:, :, None]
    Lm = jnp.where(tri, jnp.exp(diff), 0.0)              # (Q, Q, hb)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (Q, Q)
    w = scores[:, :, None] * Lm * dt[None, :, :]          # (Q, Q, hb)
    y_intra = jnp.einsum("qkh,khp->qhp", w, x)

    # ---- inter-chunk from the VMEM-resident state ----------------------------
    h_prev = h_scr[...]                                   # (hb, P, N)
    decay_from_start = jnp.exp(da_cum)                    # (Q, hb)
    y_inter = jnp.einsum("qn,qh,hpn->qhp", c, decay_from_start, h_prev)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update ---------------------------------------------------------
    decay_to_end = jnp.exp(da_total[None, :] - da_cum)    # (Q, hb)
    new_state = jnp.einsum("qn,qh,qhp->hpn", b, dt * decay_to_end, x)
    h_scr[...] = h_prev * jnp.exp(da_total)[:, None, None] + new_state

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hlast_ref[0] = h_scr[...].astype(hlast_ref.dtype)


def ssd_pallas(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) post-softplus
    a: jax.Array,    # (H,) negative
    bm: jax.Array,   # (B, S, N)
    cm: jax.Array,   # (B, S, N)
    *,
    chunk: int = 256,
    head_block: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), h_last (B,H,P,N))."""
    B, S, H, P = x.shape
    N = bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hb = head_block or min(H, max(1, (8 * 1024 * 1024) // (P * N * 4)))
    while H % hb:
        hb -= 1
    hblocks = H // hb

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = bm.reshape(B, nc, chunk, N)
    cc = cm.reshape(B, nc, chunk, N)
    a2 = jnp.broadcast_to(a[None, :], (B, H))

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    grid = (B, hblocks, nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hb, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, hb), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1, hb), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hb, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, hb, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, a2, bc, cc)
    return y.reshape(B, S, H, P), h_last
