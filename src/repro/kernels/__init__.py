"""Pallas TPU kernels for the perf-critical compute hot-spots.

* flash_attention.py — online-softmax attention with VMEM-demoted
  accumulators (pl.pallas_call + BlockSpec; the RegDem TPU adaptation)
* mamba2_ssd.py      — chunked SSD with VMEM-resident recurrent state
* ops.py             — jitted model-layout wrappers
* ref.py             — pure-jnp oracles for the allclose tests
"""
