"""Pure-jnp oracles for the Pallas kernels (the ``ref.py`` contract).

Small, obviously-correct implementations used by the per-kernel allclose
tests; no chunking, no scratch, no tiling tricks.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q: jax.Array,        # (BH, Sq, Dh)
    k: jax.Array,        # (BH, Skv, Dh)
    v: jax.Array,        # (BH, Skv, Dh)
    q_positions: jax.Array,   # (BH, Sq)
    kv_positions: jax.Array,  # (BH, Skv)
    window: Optional[int] = None,
    chunk: Optional[int] = None,
) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    qp = q_positions[:, :, None]
    kp = kv_positions[:, None, :]
    ok = jnp.logical_and(kp >= 0, kp <= qp)
    if window is not None:
        ok = jnp.logical_and(ok, kp > qp - window)
    if chunk is not None:
        ok = jnp.logical_and(ok, (kp // chunk) == (qp // chunk))
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_reference(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    a: jax.Array,    # (H,)
    bm: jax.Array,   # (B, S, N)
    cm: jax.Array,   # (B, S, N)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential state-space recurrence (the SSD ground truth)."""
    B, S, H, P = x.shape
    N = bm.shape[-1]

    def step(h, t):
        xt, dtt, bt, ct = t
        da = jnp.exp(dtt.astype(jnp.float32) * a[None, :])       # (B, H)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
                         bt.astype(jnp.float32), xt.astype(jnp.float32))
        h = h * da[:, :, None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        bm.transpose(1, 0, 2),
        cm.transpose(1, 0, 2),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_last
