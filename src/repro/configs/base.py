"""Config registry + shape cells for the assigned architectures.

Every architecture is selectable via ``--arch <id>``; ``reduced()`` derives
the small smoke-test variant of the same family; ``shape_cells()`` returns
the (shape-name, ShapeCell) pairs applicable to the arch (skips are
explicit, with reasons — see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.models import ModelConfig

ARCH_IDS = [
    "stablelm_3b",
    "gemma3_1b",
    "qwen2_7b",
    "granite_8b",
    "qwen2_moe_a2_7b",
    "llama4_scout_17b_a16e",
    "qwen2_vl_2b",
    "whisper_large_v3",
    "mamba2_370m",
    "zamba2_2_7b",
]


@dataclass(frozen=True)
class ShapeCell:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    skip_reason: Optional[str] = None

    @property
    def skipped(self) -> bool:
        return self.skip_reason is not None


SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

#: archs whose attention is full/quadratic with no sub-quadratic mode:
#: long_500k is skipped per the assignment.
_FULL_ATTENTION = {
    "stablelm_3b": "pure full attention (quadratic); long_500k skipped per assignment",
    "qwen2_7b": "pure full attention (quadratic); long_500k skipped per assignment",
    "granite_8b": "pure full attention (quadratic); long_500k skipped per assignment",
    "qwen2_moe_a2_7b": "pure full attention (quadratic); long_500k skipped per assignment",
    "qwen2_vl_2b": "pure full attention (quadratic); long_500k skipped per assignment",
    "whisper_large_v3": "enc-dec with 1500-frame encoder and 448-pos decoder; 500k ill-defined",
}


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def reduced_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_cells(arch: str) -> List[ShapeCell]:
    arch = arch.replace("-", "_")
    cells = []
    for name, (seq, batch, kind) in SHAPES.items():
        skip = None
        if name == "long_500k" and arch in _FULL_ATTENTION:
            skip = _FULL_ATTENTION[arch]
        cells.append(
            ShapeCell(name=name, seq_len=seq, global_batch=batch, kind=kind, skip_reason=skip)
        )
    return cells


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (exact for our param layout)."""
    D, L, V, F = cfg.d_model, cfg.n_layers, cfg.vocab, cfg.d_ff
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    total = V * D  # embed
    if not cfg.tie_embeddings and cfg.family in ("dense", "moe", "vlm"):
        total += D * V
    if cfg.family in ("dense", "moe", "vlm"):
        per = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D + 2 * D
        if cfg.qkv_bias:
            per += Hq * Dh + 2 * Hkv * Dh
        if cfg.moe is None:
            per += 3 * D * F
        else:
            m = cfg.moe
            per += D * m.n_experts + 3 * m.n_experts * D * m.d_ff_expert
            if m.n_shared:
                per += 3 * D * m.d_ff_shared + (D if m.shared_gate else 0)
        total += L * per
    elif cfg.family == "ssm":
        from repro.models.mamba2 import mamba_dims

        d_inner, conv_dim = mamba_dims(D, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        proj = 2 * d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
        per = D * proj + 4 * conv_dim + d_inner * D + d_inner + D
        total += L * per
    elif cfg.family == "hybrid":
        from repro.models.mamba2 import mamba_dims

        d_inner, conv_dim = mamba_dims(D, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        proj = 2 * d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
        per = D * proj + 4 * conv_dim + d_inner * D + d_inner + D
        total += L * per
        total += D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D + 3 * D * F  # shared blk
    elif cfg.family == "audio":
        per_enc = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D + 3 * D * F
        per_dec = per_enc + D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
        total += L * (per_enc + per_dec) + D * D
    return total


#: active-parameter count for MoE (MODEL_FLOPS uses N_active)
def active_param_count(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return param_count(cfg)
    m = cfg.moe
    D, L = cfg.d_model, cfg.n_layers
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    total = cfg.vocab * D
    if not cfg.tie_embeddings:
        total += D * cfg.vocab
    per = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
    per += D * m.n_experts + 3 * m.top_k * D * m.d_ff_expert
    if m.n_shared:
        per += 3 * D * m.d_ff_shared
    return total + L * per
