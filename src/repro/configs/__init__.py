from .base import (
    ARCH_IDS,
    ShapeCell,
    active_param_count,
    all_configs,
    get_config,
    param_count,
    reduced_config,
    shape_cells,
)

__all__ = [
    "ARCH_IDS",
    "ShapeCell",
    "active_param_count",
    "all_configs",
    "get_config",
    "param_count",
    "reduced_config",
    "shape_cells",
]
