"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) vocab=151936.

MoE: 60 routed experts (d_ff 1408) top-4 + shared expert block of 4x1408
with a sigmoid shared-expert gate. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

import dataclasses

from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared=4,
        d_ff_shared=4 * 1408,
        shared_gate=True,
        norm_topk=True,
    ),
    notes=(
        "60 routed top-4 + 4 shared experts; E=60 does not divide model=16 "
        "so experts use TP-inside-expert sharding (ff_expert over model); "
        "full attention — long_500k skipped per assignment"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2_moe_smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=96, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1,
                  d_ff_shared=192, shared_gate=True),
)
