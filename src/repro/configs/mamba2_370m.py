"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280.

SSD (state-space duality): d_inner = 2*d_model = 2048, head_dim 64 ->
32 SSM heads, d_state 128. [arXiv:2405.21060; unverified]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    ssm_chunk=256,
    notes=(
        "attention-free; RegDem-kernel demotion applies to the SSD chunk "
        "state (see DESIGN.md §Arch-applicability); long_500k RUNS (O(1) "
        "state decode)"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="mamba2_smoke", n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_chunk=16,
)
