"""whisper-large-v3 [audio]: 32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.

Encoder-decoder; the conv mel frontend is a STUB (``input_specs()`` yields
precomputed frame embeddings, 1500 frames = 30 s).  Shape-cell adaptation
(DESIGN.md): the seq_len budget is split as 1500 encoder frames + the rest
decoder positions; long_500k is skipped (decoder max position 448).
[arXiv:2212.04356; unverified]
"""

import dataclasses

from repro.models import ModelConfig

N_FRAMES = 1500

CONFIG = ModelConfig(
    name="whisper_large_v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    notes=(
        "enc-dec backbone; conv frontend stubbed with frame embeddings; "
        "decode cells: 1500 enc frames + (seq_len-1500) decoder budget; "
        "long_500k skipped (decoder max pos 448, quadratic cross-attn)"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="whisper_smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
)
