"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) vocab=202048.

MoE 16 experts top-1 + shared expert (d_ff 8192); iRoPE: chunked local
attention (8192) with NoPE global layers every 4th layer.  The text
backbone only — early-fusion vision is out of the assigned scope.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

import dataclasses

from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4_scout_17b_a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    head_dim=128,
    rope_theta=500_000.0,
    global_period=4,
    attn_chunk=8192,
    nope_on_global=True,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8192,
        n_shared=1,
        d_ff_shared=8192,
        norm_topk=False,
    ),
    notes=(
        "16 routed top-1 + shared expert; E=16 divides model=16 -> clean EP; "
        "chunked 8k attention -> long_500k RUNS (sub-quadratic)"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="llama4_scout_smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, attn_chunk=32,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1,
                  d_ff_shared=128, norm_topk=False),
)
