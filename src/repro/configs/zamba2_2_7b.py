"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000.

Mamba2 backbone (d_state 64, d_inner 5120, head_dim 64 -> 80 SSM heads)
with a weight-SHARED full-attention block applied every 6 layers.
[arXiv:2411.15242; hf]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32_000,
    tie_embeddings=True,
    ssm_state=64,
    ssm_heads=80,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_period=6,
    notes=(
        "Mamba2 + shared attn every 6 layers (9 applications, one weight "
        "set); long_500k RUNS (SSM decode O(1), attn decode O(S) reads)"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="zamba2_smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_chunk=16, attn_period=3,
)
