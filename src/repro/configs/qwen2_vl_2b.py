"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

M-RoPE (temporal/height/width sections) with dynamic-resolution patches;
the vision frontend is a STUB — ``input_specs()`` provides precomputed
patch embeddings per the assignment. [arXiv:2409.12191; hf]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    mrope=True,
    rope_theta=1_000_000.0,
    notes=(
        "M-RoPE backbone; patch embeddings precomputed (frontend stub); "
        "full attention — long_500k skipped per assignment"
    ),
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2_vl_smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256,
)

#: patch tokens occupying the sequence prefix in vlm shape cells
N_PATCHES = 256
