"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA with QKV bias. [arXiv:2407.10671; hf]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    notes="GQA kv=4, QKV bias; full attention — long_500k skipped per assignment",
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2_7b_smoke", n_layers=2, d_model=56, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256,
)
