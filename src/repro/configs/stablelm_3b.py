"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b family; unverified]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope_theta=10_000.0,
    notes="MHA (kv=32); full attention — long_500k skipped per assignment",
)

REDUCED = dataclasses.replace(
    CONFIG, name="stablelm_3b_smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
)
