"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

Llama-architecture code model. [arXiv:2405.04324; hf]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite_8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    rope_theta=10_000_000.0,
    notes="llama-arch; full attention — long_500k skipped per assignment",
)

REDUCED = dataclasses.replace(
    CONFIG, name="granite_8b_smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab=256,
)
