"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global attention (window 512), 10k local / 1M global RoPE theta.
[hf:google/gemma-3-1b-pt; unverified]
"""

import dataclasses

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262_144,
    head_dim=256,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    global_period=6,          # 5 local : 1 global
    window=512,
    notes="5:1 local:global (window 512); long_500k RUNS (sub-quadratic local)",
)

REDUCED = dataclasses.replace(
    CONFIG, name="gemma3_1b_smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab=512, head_dim=16, window=16,
)
